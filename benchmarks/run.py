"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
- ``us_per_call`` — measured wall time of the jitted engine execution on
  this host (one CPU device; compile excluded);
- ``derived``     — the figure's actual metric (modeled throughput, NRS,
  NTB, ops, ...), computed from the engines' exact counts via the cost
  model in repro.benchlib (see its docstring for the constants).

Figures covered:
  fig4_loadstats        query-load statistics
  fig5_throughput       throughput vs concurrent clients, per load
  fig5f_timeouts        overflow/timeout analogue count, union load
  fig6_server_load      server CPU proxy vs clients, union load
  fig7_network          NRS + NTB per interface per load (64 clients)
  fig8_latency          QET / QRT per load (64 clients)
  fig_sched_throughput  scheduler vs serial serving: measured wall time,
                        fragment-cache hit rate and batch occupancy per
                        load at 16/64/128 simulated clients, with p50/p99
                        per-query latency from the registry histogram;
                        also writes the BENCH_sched.json artifact (CI
                        uploads it)
  fig_sched_trace       traced serving smoke: one multi-client stream
                        with full observability on, exported as a
                        Perfetto-loadable Chrome trace
                        (TRACE_sched_smoke.json; CI uploads it)
  fig_capacity          warm-run wall with the capacity planner on vs off
                        on the union load (blind 4x ladder baseline);
                        writes BENCH_capacity.json (CI uploads it)
  fig_dist_sched        mesh-spanning scheduler waves vs single-host vmap
                        waves on the same streams (run with 8 forced host
                        devices in CI); writes BENCH_dist_sched.json
  fig_shard_sched       sharded-store scheduler waves vs replicated mesh
                        waves: per-device store bytes, wall, measured
                        gather traffic fed through the throughput model
                        (run with 8 forced host devices in CI); writes
                        BENCH_shard_sched.json
  fig_live_ingest       sustained serving under live writes: delta-overlay
                        ingest (merged base+delta probes, carry-over,
                        threshold compaction) vs stop-the-world rebuild at
                        write rates {0.1,1,10}%/window; writes
                        BENCH_live_ingest.json (CI uploads it)
  fig_kernels           calibrated kernel microbench: prefetch vs dense
                        run_probe, point-probe calibration fit (what
                        kops.probe_op_cost charges per tile pass),
                        fingerprint/replay, k-way merge vs lexsort at
                        shard counts {2,4,6,8} (6 exercises the non-pow2
                        padded schedule); writes BENCH_kernels.json
                        (CI uploads it; CPU runs in interpret mode at
                        reduced sizes and keep the guess constant)
  kernels               sorted_probe / run_probe / flash_attention microbench
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, "src")  # allow `python -m benchmarks.run` from repo root

from repro.benchlib import CostModel, modeled_query_seconds  # noqa: E402
from repro.core import count_stars  # noqa: E402
from repro.core.patterns import star_decomposition  # noqa: E402

from benchmarks.common import (CLIENTS, INTERFACES, LOADS,  # noqa: E402
                               SCHED_CLIENTS, bench_graph, bench_load,
                               capacity_planner_vs_blind, endpoint_serve,
                               engine, load_run, sched_mesh_vs_vmap,
                               sched_shard_vs_replicated, sched_vs_serial,
                               timed_run)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


# ------------------------------------------------------------------ Fig. 4

def fig4_loadstats() -> None:
    for load in LOADS:
        qs = bench_load(load)
        wall, stats_list = load_run(load, "spf")
        n_res, n_tp_per_star, n_stars = [], [], []
        for q, stats in zip(qs, stats_list):
            n_res.append(int(stats.n_results))
            sizes = [len(s.branches) for s in star_decomposition(q)]
            big = [b for b in sizes if b >= 2]
            n_tp_per_star.extend(big or [1])
            n_stars.append(count_stars(q))
        emit(f"fig4_loadstats/{load}", 1e6 * wall,
             f"results_mean={np.mean(n_res):.1f};"
             f"tp_per_star={np.mean(n_tp_per_star):.2f};"
             f"stars={np.mean(n_stars):.2f}")


# ------------------------------------------------------------------ Fig. 5

def fig5_throughput() -> None:
    for load in LOADS:
        for iface in INTERFACES:
            wall, per_q = load_run(load, iface)
            for c in CLIENTS:
                mean_s = np.mean([modeled_query_seconds(s, c) for s in per_q])
                tput = c * 60.0 / mean_s
                emit(f"fig5_throughput/{load}/{iface}/clients{c}",
                     1e6 * wall, f"queries_per_min={tput:.1f}")


def fig5f_timeouts() -> None:
    for iface in INTERFACES:
        wall, stats_list = load_run("union", iface)
        # timeout analogue: modeled 128-client QET over 600 s, or overflow
        n_to = sum(1 for s in stats_list
                   if modeled_query_seconds(s, 128) > 600 or bool(s.overflow))
        emit(f"fig5f_timeouts/union/{iface}", 1e6 * wall,
             f"timeouts={n_to}/{len(stats_list)}")


# ------------------------------------------------------------------ Fig. 6

def fig6_server_load() -> None:
    cm = CostModel()
    for iface in INTERFACES:
        _, stats = load_run("union", iface)
        for c in CLIENTS:
            mean_q = np.mean([modeled_query_seconds(s, c) for s in stats])
            server_s = np.mean([int(s.server_ops) * cm.op_s for s in stats])
            util = min(1.0, c * server_s / (mean_q * cm.server_cores))
            emit(f"fig6_server_load/union/{iface}/clients{c}", 0.0,
                 f"cpu_util={100 * util:.1f}%")


# ------------------------------------------------------------------ Fig. 7

def fig7_network() -> None:
    for load in LOADS:
        qs = bench_load(load)
        for iface in INTERFACES:
            wall, stats_list = load_run(load, iface)
            nrs = sum(int(s.nrs) for s in stats_list)
            ntb = sum(int(s.ntb) for s in stats_list)
            n = len(stats_list)
            emit(f"fig7_network/{load}/{iface}", 1e6 * wall,
                 f"nrs_mean={nrs / n:.1f};ntb_mean_bytes={ntb / n:.0f}")


# ------------------------------------------------------------------ Fig. 8

def fig8_latency() -> None:
    cm = CostModel()
    for load in LOADS:
        for iface in INTERFACES:
            wall, stats_list = load_run(load, iface)
            qets, qrts = [], []
            for stats in stats_list:
                qet = modeled_query_seconds(stats, 64)
                # QRT: first result lands before the final page transfer
                # completes (paper Sec. 6.1: QRT ~= QET for all interfaces)
                qrt = qet - int(stats.ntb) / cm.bw_bytes_s * 0.5
                qets.append(qet)
                qrts.append(max(qrt, 0.0))
            emit(f"fig8_latency/{load}/{iface}", 1e6 * wall,
                 f"qet_ms={1e3 * np.mean(qets):.1f};"
                 f"qrt_ms={1e3 * np.mean(qrts):.1f}")


# ------------------------------------------------- scheduler vs serial

def fig_sched_throughput() -> None:
    """Measured (not modeled) serving comparison: the scheduler's batched,
    cache-aware path against the serial ``run``-per-request loop, on the
    same interleaved multi-client request streams.  Emits CSV rows and the
    ``BENCH_sched.json`` artifact with one record per (load, clients).

    Environment knobs (CI smoke uses the defaults):
      BENCH_SCHED_LOADS    comma list, default all five loads
      BENCH_SCHED_CLIENTS  comma list, default "16,64,128"
    """
    loads = tuple(
        s for s in os.environ.get("BENCH_SCHED_LOADS", ",".join(LOADS)).split(",")
        if s)
    clients = tuple(
        int(c) for c in os.environ.get(
            "BENCH_SCHED_CLIENTS", ",".join(map(str, SCHED_CLIENTS))).split(","))
    records = []
    for load in loads:
        for c in clients:
            r = sched_vs_serial(load, c)
            per_q = r.pop("stats")
            mean_s = np.mean([modeled_query_seconds(s, c, occupancy=max(
                r["occupancy"], 1.0)) for s in per_q])
            r["modeled_queries_per_min"] = c * 60.0 / mean_s
            # per-query latency quantiles, straight from the registry's
            # sched.query_latency_s histogram over the measured pass
            r["latency_p50_ms"] = 1e3 * r.pop("latency_p50_s")
            r["latency_p99_ms"] = 1e3 * r.pop("latency_p99_s")
            records.append(r)
            emit(f"fig_sched_throughput/{load}/clients{c}",
                 1e6 * r["sched_s"] / max(r["requests"], 1),
                 f"serial_s={r['serial_s']:.3f};sched_s={r['sched_s']:.3f};"
                 f"speedup={r['speedup']:.2f};hit_rate={r['hit_rate']:.3f};"
                 f"occupancy={r['occupancy']:.2f};"
                 f"p50_ms={r['latency_p50_ms']:.2f};"
                 f"p99_ms={r['latency_p99_ms']:.2f};"
                 f"identical={int(r['byte_identical'])}")
    out = os.environ.get("BENCH_SCHED_JSON", "BENCH_sched.json")
    with open(out, "w") as f:
        json.dump({"figure": "fig_sched_throughput", "records": records}, f,
                  indent=2)
    print(f"# wrote {out} ({len(records)} records)", file=sys.stderr)


# ------------------------------------------------- capacity planning

def fig_capacity() -> None:
    """Warm-run wall with the capacity planner on vs off on the union load
    (the load whose non-selective queries overflow the base capacity and
    re-climb the blind 4x ladder on every warm run).  Per-query warm
    samples, extrapolated to the load — never a serial client-stream
    replay.  Emits CSV rows and the ``BENCH_capacity.json`` artifact; the
    acceptance gate reads ``best_overflow_speedup`` (>= 5x for at least
    one overflow query — the fat-unit-dominated q1 tops out ~3x by
    construction, see the per-query records) and ``byte_identical``.

    Environment knobs (CI smoke restricts the query count):
      BENCH_CAP_LOAD     load name, default "union"
      BENCH_CAP_QUERIES  int, default all queries of the load
      BENCH_CAP_REPEATS  warm repeats per query, default 2
      BENCH_CAPACITY_JSON  output path, default BENCH_capacity.json
    """
    load = os.environ.get("BENCH_CAP_LOAD", "union")
    n_q = os.environ.get("BENCH_CAP_QUERIES")
    repeats = int(os.environ.get("BENCH_CAP_REPEATS", "2"))
    rec = capacity_planner_vs_blind(load, int(n_q) if n_q else None,
                                    repeats=repeats)
    for r in rec["records"]:
        emit(f"fig_capacity/{load}/q{r['query']}", 1e6 * r["planned_s"],
             f"blind_s={r['blind_s']:.3f};planned_s={r['planned_s']:.3f};"
             f"speedup={r['speedup']:.2f};"
             f"max_unit_cap={r['max_unit_cap']};"
             f"overflow={int(r['overflows_base_cap'])};"
             f"identical={int(r['byte_identical'])}")
    emit(f"fig_capacity/{load}/aggregate",
         1e6 * rec["extrapolated_load_planned_s"],
         f"load_blind_s={rec['extrapolated_load_blind_s']:.3f};"
         f"load_planned_s={rec['extrapolated_load_planned_s']:.3f};"
         f"best_overflow_speedup={rec['best_overflow_speedup']:.2f};"
         f"mean_overflow_speedup={rec['mean_overflow_speedup']:.2f};"
         f"identical={int(rec['byte_identical'])}")
    out = os.environ.get("BENCH_CAPACITY_JSON", "BENCH_capacity.json")
    with open(out, "w") as f:
        json.dump({"figure": "fig_capacity", **rec}, f, indent=2)
    print(f"# wrote {out} ({len(rec['records'])} records)", file=sys.stderr)


# ------------------------------------------------- distributed scheduler

def fig_dist_sched() -> None:
    """Mesh-spanning scheduler waves vs single-host vmap waves on the same
    interleaved multi-client streams.  Emits CSV rows and the
    ``BENCH_dist_sched.json`` artifact with one record per
    (load, clients); run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (or on a real
    pod) so waves actually span devices — on one device the mesh lowering
    still runs and the record documents the shard_map overhead floor.

    Environment knobs (the CI matrix job uses the defaults):
      BENCH_DIST_LOADS    comma list, default "2-stars,union"
      BENCH_DIST_CLIENTS  comma list, default "16,64"
      BENCH_DIST_JSON     output path, default BENCH_dist_sched.json
    """
    import jax

    loads = tuple(
        s for s in os.environ.get("BENCH_DIST_LOADS", "2-stars,union").split(",")
        if s)
    clients = tuple(
        int(c) for c in os.environ.get("BENCH_DIST_CLIENTS", "16,64").split(","))
    records = []
    for load in loads:
        for c in clients:
            r = sched_mesh_vs_vmap(load, c)
            per_q = r.pop("stats")
            mean_s = np.mean([modeled_query_seconds(s, c, occupancy=max(
                r["occupancy"], 1.0)) for s in per_q])
            r["modeled_queries_per_min"] = c * 60.0 / mean_s
            records.append(r)
            emit(f"fig_dist_sched/{load}/clients{c}",
                 1e6 * r["mesh_s"] / max(r["requests"], 1),
                 f"devices={r['n_devices']};vmap_s={r['vmap_s']:.3f};"
                 f"mesh_s={r['mesh_s']:.3f};"
                 f"mesh_wave_frac={r['mesh_wave_fraction']:.2f};"
                 f"hit_rate={r['hit_rate']:.3f};"
                 f"occupancy={r['occupancy']:.2f};"
                 f"identical={int(r['byte_identical'])}")
    out = os.environ.get("BENCH_DIST_JSON", "BENCH_dist_sched.json")
    with open(out, "w") as f:
        json.dump({"figure": "fig_dist_sched",
                   "n_devices": len(jax.devices()), "records": records}, f,
                  indent=2)
    print(f"# wrote {out} ({len(records)} records)", file=sys.stderr)


# ------------------------------------------------- sharded scheduler

def fig_shard_sched() -> None:
    """Sharded-store scheduler waves vs replicated mesh waves on the same
    interleaved multi-client streams (the PR 5 acceptance figure): wall
    time, per-device store bytes (the sharded mode's point — they shrink
    ~linearly with the shard count at byte-identical results), measured
    per-unit gather traffic, hit rate and occupancy.  Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (or a real
    pod) so shards land on distinct devices — on one device only
    ``n_shards=1`` is valid and the record documents the collective
    overhead floor.  Writes the ``BENCH_shard_sched.json`` artifact.

    The modeled throughput charges the sharded path's measured
    ``gather_bytes`` against the pod interconnect (``CostModel``) — the
    replicated transfer model would be silently optimistic for it.

    Environment knobs (the CI matrix job restricts these):
      BENCH_SHARD_LOADS    comma list, default "2-stars,union"
      BENCH_SHARD_CLIENTS  comma list, default "16"
      BENCH_SHARD_COUNTS   comma list, default "1,2,4" (device divisors)
      BENCH_SHARD_JSON     output path, default BENCH_shard_sched.json
    """
    import jax

    cm = CostModel()
    loads = tuple(
        s for s in os.environ.get("BENCH_SHARD_LOADS", "2-stars,union").split(",")
        if s)
    clients = tuple(
        int(c) for c in os.environ.get("BENCH_SHARD_CLIENTS", "16").split(","))
    n_dev = len(jax.devices())
    shards = tuple(
        s for s in (int(x) for x in
                    os.environ.get("BENCH_SHARD_COUNTS", "1,2,4").split(","))
        if s <= n_dev and n_dev % s == 0)
    records = []
    for load in loads:
        for c in clients:
            for s in shards:
                r = sched_shard_vs_replicated(load, c, s)
                per_q = r.pop("stats")
                gather_s = r["gather_bytes"] / cm.pod_bw_bytes_s
                mean_s = np.mean([modeled_query_seconds(
                    st, c, occupancy=max(r["occupancy"], 1.0))
                    for st in per_q]) + gather_s / max(r["requests"], 1)
                r["modeled_queries_per_min"] = c * 60.0 / mean_s
                records.append(r)
                emit(f"fig_shard_sched/{load}/clients{c}/shards{s}",
                     1e6 * r["sharded_s"] / max(r["requests"], 1),
                     f"devices={r['n_devices']};"
                     f"store_mb_per_dev={r['store_bytes_per_device_sharded'] / 1e6:.2f};"
                     f"shrink={r['store_bytes_shrink']:.2f};"
                     f"repl_s={r['replicated_s']:.3f};"
                     f"shard_s={r['sharded_s']:.3f};"
                     f"shard_wave_frac={r['shard_wave_fraction']:.2f};"
                     f"gather_mb={r['gather_bytes'] / 1e6:.2f};"
                     f"hit_rate={r['hit_rate']:.3f};"
                     f"occupancy={r['occupancy']:.2f};"
                     f"identical={int(r['byte_identical'])}")
    out = os.environ.get("BENCH_SHARD_JSON", "BENCH_shard_sched.json")
    with open(out, "w") as f:
        json.dump({"figure": "fig_shard_sched",
                   "n_devices": n_dev, "records": records}, f, indent=2)
    print(f"# wrote {out} ({len(records)} records)", file=sys.stderr)


# ------------------------------------------------- live ingest

def fig_live_ingest() -> None:
    """Sustained serving under live writes: the delta-overlay ingest path
    (merged base+delta probes, epoch-pipelined waves, cache/HWM
    carry-over, threshold compaction) against the stop-the-world
    rebuild baseline, at write rates of {0.1, 1, 10} percent of the
    store per write window.  Emits CSV rows and the
    ``BENCH_live_ingest.json`` artifact; the acceptance gate reads the
    1%-rate record's ``speedup`` (>= 3x sustained throughput vs
    rebuild) with ``cache_carryover > 0`` and ``byte_identical``.

    Read the rate sweep as regimes, not a dose-response curve: windows
    whose stray predicate intersects the read working set pay the
    recompute (and first-time delta-shape compiles of its retry rungs)
    that any system pays when reads meet writes, and the 10% rate
    crosses the compaction threshold mid-measurement — the fold plus
    its re-trace lands in the timed window, which is the honest cost of
    sustained high-rate ingest.  The carry-over win is the
    steady-state skewed-write regime the 1% record captures.

    Environment knobs (CI smoke restricts clients/rounds):
      BENCH_INGEST_LOAD     load name, default "2-stars"
      BENCH_INGEST_CLIENTS  int, default 16
      BENCH_INGEST_RATES    comma list of percent/window, default "0.1,1,10"
      BENCH_INGEST_ROUNDS   write windows per rate, default 3
      BENCH_INGEST_JSON     output path, default BENCH_live_ingest.json
    """
    from benchmarks.common import live_ingest_serve

    load = os.environ.get("BENCH_INGEST_LOAD", "2-stars")
    clients = int(os.environ.get("BENCH_INGEST_CLIENTS", "16"))
    rates = tuple(
        float(r) for r in os.environ.get("BENCH_INGEST_RATES",
                                         "0.1,1,10").split(",") if r)
    rounds = int(os.environ.get("BENCH_INGEST_ROUNDS", "3"))
    records = []
    for rate in rates:
        r = live_ingest_serve(load, clients, rate, rounds=rounds)
        r["latency_p50_ms"] = 1e3 * r.pop("latency_p50_s")
        r["latency_p99_ms"] = 1e3 * r.pop("latency_p99_s")
        records.append(r)
        emit(f"fig_live_ingest/{load}/rate{rate:g}pct",
             1e6 * r["live_total_s"] / max(r["rounds"]
                                           * r["requests_per_window"], 1),
             f"live_qpm={r['live_queries_per_min']:.1f};"
             f"rebuild_qpm={r['rebuild_queries_per_min']:.1f};"
             f"speedup={r['speedup']:.2f};"
             f"p50_ms={r['latency_p50_ms']:.2f};"
             f"p99_ms={r['latency_p99_ms']:.2f};"
             f"carryover={r['cache_carryover']};"
             f"swept={r['cache_swept']};"
             f"compactions={r['compactions']};"
             f"identical={int(r['byte_identical'])}")
    out = os.environ.get("BENCH_INGEST_JSON", "BENCH_live_ingest.json")
    with open(out, "w") as f:
        json.dump({"figure": "fig_live_ingest", "records": records}, f,
                  indent=2)
    print(f"# wrote {out} ({len(records)} records)", file=sys.stderr)


# ------------------------------------------------- calibrated kernel bench

def fig_kernels() -> None:
    """Kernel-level microbench + the cost-model calibration artifact.

    Times the PR 6 kernel set through their real entry points — the
    scalar-prefetch vs dense ``run_probe`` variants on dense- and
    clustered-window workloads, the fused point probe across column
    lengths (the calibration fit), the wave fingerprint and cache-replay
    primitives, and the k-way shard merge against the replicated-lexsort
    baseline at several shard counts (single-process: the merge schedule
    one device executes, partner blocks prebuilt) — and writes
    ``BENCH_kernels.json``.

    The artifact's ``calibration.tile_pass_ops`` is what
    ``kops.probe_op_cost`` charges per probe tile pass
    (``repro.kernels.calibration`` is the read side): on a real TPU
    pipeline it is the fitted per-pass wall slope divided by
    ``CostModel.op_s`` with ``"source": "measured"``; interpret-mode
    (CPU) runs deliberately keep the historical guess with
    ``"source": "guess"`` — interpreter walls measure Python, not the
    pipeline — so CI's artifact never perturbs modeled costs.

    Runs on CPU CI in Pallas interpret mode at reduced sizes (the
    defaults below scale down off-TPU).  Environment knobs:
      BENCH_KERNELS_KEYS     sorted-column length (default 1M TPU / 128k)
      BENCH_KERNELS_QUERIES  probe rows           (default 4k TPU / 512)
      BENCH_KERNELS_TRIM     per-shard merge rows (default 4k TPU / 1k)
      BENCH_KERNELS_SHARDS   comma list, default "2,4,6,8" (non-pow2
                             counts run the padded fold pre-round)
      BENCH_KERNELS_REPEATS  timing repeats (default 10 TPU / 3)
      BENCH_KERNELS_JSON     output path, default BENCH_kernels.json
    """
    import jax
    import jax.numpy as jnp

    from repro import benchlib
    from repro.core import stepper
    from repro.kernels import calibration, ops, ref
    from repro.kernels.run_probe import (DEFAULT_R_TILE, DEFAULT_V_TILE,
                                         run_probe_pallas,
                                         run_probe_prefetch_pallas)
    from repro.kernels.sorted_probe import DEFAULT_K_TILE, sorted_probe_pallas

    backend = jax.default_backend()
    interp = ops._interpret()
    on_tpu = backend == "tpu"
    n_keys = int(os.environ.get("BENCH_KERNELS_KEYS",
                                1_000_000 if on_tpu else 131_072))
    n_q = int(os.environ.get("BENCH_KERNELS_QUERIES",
                             4096 if on_tpu else 512))
    trim = int(os.environ.get("BENCH_KERNELS_TRIM", 4096 if on_tpu else 1024))
    repeats = int(os.environ.get("BENCH_KERNELS_REPEATS",
                                 10 if on_tpu else 3))
    shard_counts = tuple(
        int(s) for s in os.environ.get("BENCH_KERNELS_SHARDS",
                                       "2,4,6,8").split(",") if s)
    records: list[dict] = []

    def timed(fn, *args):
        out = fn(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = fn(*args)
            jax.tree_util.tree_leaves(out)[0].block_until_ready()
        return (time.perf_counter() - t0) / repeats, out

    def record(name: str, wall_s: float, derived: str, **extra) -> None:
        emit(f"fig_kernels/{name}", 1e6 * wall_s, derived)
        records.append({"name": name, "us_per_call": 1e6 * wall_s,
                        "derived": derived, **extra})

    rng = np.random.default_rng(0)
    values = jnp.asarray(np.sort(rng.integers(0, 4 * n_keys, n_keys))
                         .astype(np.int64))

    # --- run_probe: prefetch vs dense on two window shapes --------------
    # dense windows: each row's run spans ~1/8 of the column, scattered —
    # the prefetch window per row block covers most value tiles, so both
    # variants stream nearly everything.  clustered windows: short runs,
    # sorted starts (how the engine actually probes: runs of one
    # predicate segment) — a row block's union window is a few tiles and
    # the prefetch grid skips the rest.
    lo_dense = rng.integers(0, n_keys, n_q)
    hi_dense = np.minimum(n_keys, lo_dense + rng.integers(0, n_keys // 8, n_q))
    lo_clust = np.sort(rng.integers(0, n_keys, n_q))
    hi_clust = np.minimum(n_keys, lo_clust + rng.integers(0, 64, n_q))
    targets = jnp.asarray(rng.integers(0, 4 * n_keys, n_q).astype(np.int64))
    n_v_tiles = -(-n_keys // DEFAULT_V_TILE)
    for case, lo64, hi64 in (("densewin", lo_dense, hi_dense),
                             ("clustwin", lo_clust, hi_clust)):
        lo = jnp.asarray(lo64.astype(np.int64))
        hi = jnp.asarray(hi64.astype(np.int64))
        pos_ref, hit_ref = ref.run_probe_ref(values, lo, hi, targets)
        # fraction of value tiles a prefetch row block actually streams
        blk_lo = (lo64 // DEFAULT_V_TILE).reshape(-1, DEFAULT_R_TILE) \
            if n_q % DEFAULT_R_TILE == 0 else (lo64 // DEFAULT_V_TILE)[None]
        blk_hi = (np.maximum(hi64 - 1, 0) // DEFAULT_V_TILE).reshape(
            blk_lo.shape)
        tile_frac = float(np.mean(np.maximum(
            blk_hi.max(1) - blk_lo.min(1) + 1, 0)) / n_v_tiles)
        for variant, fn in (("dense", run_probe_pallas),
                            ("prefetch", run_probe_prefetch_pallas)):
            wall, (pos, hit) = timed(
                lambda v, l, h, t, fn=fn: fn(v, l, h, t, interpret=interp),
                values, lo, hi, targets)
            same = bool(np.array_equal(np.asarray(pos), np.asarray(pos_ref))
                        and np.array_equal(np.asarray(hit),
                                           np.asarray(hit_ref)))
            record(f"run_probe_{variant}/{case}", wall,
                   f"backend={backend};interpret={int(interp)};"
                   f"window_tile_frac={tile_frac:.3f};identical={int(same)}",
                   identical=same, window_tile_frac=tile_frac)

    # --- point probe across column lengths: the calibration fit ---------
    cal_sizes = sorted({max(DEFAULT_K_TILE, n_keys // 4), n_keys // 2,
                        n_keys})
    q_cal = jnp.asarray(rng.integers(0, 4 * n_keys, n_q).astype(np.int64))
    passes, walls = [], []
    for size in cal_sizes:
        wall, _ = timed(lambda k, q: sorted_probe_pallas(k, q,
                                                         interpret=interp),
                        values[:size], q_cal)
        passes.append(max(1, -(-size // DEFAULT_K_TILE)))
        walls.append(wall)
        record(f"sorted_probe/n{size}", wall,
               f"backend={backend};tile_passes={passes[-1]}")
    fitted = benchlib.fit_tile_pass_ops(passes, walls)
    if on_tpu and not interp and ops._use_pallas():
        tile_pass_ops, source = fitted, "measured"
    else:
        tile_pass_ops = float(calibration.DEFAULT_TILE_PASS_OPS)
        source = "guess"
    record("probe_calibration", sum(walls),
           f"tile_pass_ops={tile_pass_ops:.3g};source={source};"
           f"fitted_ops={fitted:.3g}")

    # --- merged base+delta probe: wall vs delta fraction -----------------
    # the live-ingest hot path: every dispatched probe adds an eqrange
    # over the sorted insert keys and a rank count over the tombstone
    # positions on top of its base window.  Timed through the dispatch
    # layer at delta sizes of {1, 10, 50}% of the base column, parity
    # checked against the numpy twin.
    base_lo64 = rng.integers(0, n_keys, n_q)
    base_hi64 = np.minimum(n_keys, base_lo64 + rng.integers(0, 256, n_q))
    d_lo = jnp.asarray(base_lo64.astype(np.int32))
    d_hi = jnp.asarray(base_hi64.astype(np.int32))
    d_q64 = rng.integers(0, 4 * n_keys, n_q)
    d_q64[:n_q // 2] = np.asarray(values)[
        rng.integers(0, n_keys, n_q // 2)]  # half exact hits
    d_q = jnp.asarray(d_q64.astype(np.int64))
    for frac in (0.01, 0.1, 0.5):
        m = max(8, int(frac * n_keys))
        ins64 = np.sort(rng.integers(0, 4 * n_keys, m).astype(np.int64))
        tomb64 = np.sort(rng.choice(n_keys, min(m // 2, n_keys),
                                    replace=False).astype(np.int32))
        ins = jnp.asarray(ins64)
        tomb = jnp.asarray(tomb64)
        want = ref.delta_probe_np(ins64, tomb64, np.asarray(d_q64),
                                  base_lo64.astype(np.int32),
                                  np.minimum(n_keys, base_hi64)
                                  .astype(np.int32))
        wall, got = timed(
            lambda i, t, q, lo, hi: ops.delta_probe(i, t, q, lo, hi),
            ins, tomb, d_q, d_lo, d_hi)
        same = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(got, want))
        record(f"delta_probe/frac{frac:g}", wall,
               f"backend={backend};interpret={int(interp)};"
               f"delta_keys={m};identical={int(same)}",
               identical=bool(same), delta_frac=frac)

    # --- wave fingerprint + cache replay --------------------------------
    block = jnp.asarray(rng.integers(0, 1 << 20, (trim, 4)).astype(np.int32))
    valid = jnp.asarray(np.arange(trim) < trim * 3 // 4)
    wall, _ = timed(jax.jit(ops.fingerprint_rows), block, valid)
    record(f"fingerprint/{trim}x4", wall, f"backend={backend}")
    m = trim // 2
    src = jnp.asarray(rng.integers(0, trim * 3 // 4, m).astype(np.int32))
    written = jnp.asarray(rng.integers(0, 1 << 20, (m, 2)).astype(np.int32))
    n_out = jnp.asarray(m, jnp.int32)
    replay = jax.jit(lambda s, sr, w, n: ops.replay_delta(s, sr, w, n,
                                                          (2, 3)))
    wall, _ = timed(replay, block, src, written, n_out)
    record(f"replay/{trim}x4", wall, f"backend={backend}")

    # --- k-way merge vs replicated lexsort ------------------------------
    # single-process: the merge schedule ONE device runs in the
    # recursive-doubling collective (non-pow2 counts add the fold
    # pre-round — ``stepper.gather_merge_kway``'s padded schedule — then
    # log2(base) pairwise merges of doubling size, partner blocks
    # prebuilt untimed) against that device's alternative under
    # all_gather: one lexsort of the full S*trim block.
    sort_cols = (0, 1)
    for S in shard_counts:
        if S < 2:
            print(f"# skipping shards{S}: merge needs >= 2 blocks",
                  file=sys.stderr)
            continue
        n_valid = S * trim * 3 // 5
        g = np.full((S * trim, 4), -1, np.int32)
        g[:n_valid, 0] = np.sort(rng.integers(0, n_valid // 4, n_valid))
        g[:n_valid, 1] = np.arange(n_valid)  # (c0, c1) unique + lexsorted
        g[:n_valid, 2:] = rng.integers(0, 1 << 20, (n_valid, 2))
        owner = rng.integers(0, S, n_valid)
        blocks, valids = [], []
        for s in range(S):
            mine = g[:n_valid][owner == s][:trim]
            b = np.full((trim, 4), -1, np.int32)
            b[:len(mine)] = mine
            blocks.append(jnp.asarray(b))
            valids.append(jnp.asarray(np.arange(trim) < len(mine)))
        gathered = jnp.concatenate(blocks)
        valid_g = jnp.concatenate(valids)
        wall_lex, (r_lex, v_lex) = timed(
            jax.jit(lambda r, v: stepper.lexsort_rows(r, v, sort_cols)),
            gathered, valid_g)
        base_n = 1 << (S.bit_length() - 1)
        rem = S - base_n
        # effective blocks after the fold pre-round: extras base+i folded
        # into i, everyone else padded by an empty-block merge (the
        # uniform-shape SPMD schedule)
        empty_r = jnp.full((trim, 4), -1, jnp.int32)
        empty_v = jnp.zeros((trim,), bool)
        if rem:
            eff = [stepper.merge_sorted_blocks(
                blocks[i], valids[i],
                blocks[base_n + i] if i < rem else empty_r,
                valids[base_n + i] if i < rem else empty_v,
                sort_cols) for i in range(base_n)]
        else:
            eff = [(blocks[i], valids[i]) for i in range(base_n)]
        # device 0's partners: the merged effective block of [2^r, 2^(r+1))
        partners = []
        for r in range(base_n.bit_length() - 1):
            d = 1 << r
            p_r, p_v = eff[d]
            for s in range(d + 1, 2 * d):
                p_r, p_v = stepper.merge_sorted_blocks(p_r, p_v, eff[s][0],
                                                       eff[s][1], sort_cols)
            partners.append((p_r, p_v))

        def kway_chain(mine_r, mine_v, *flat):
            for i in range(0, len(flat), 2):
                mine_r, mine_v = stepper.merge_sorted_blocks(
                    mine_r, mine_v, flat[i], flat[i + 1], sort_cols)
            return mine_r, mine_v

        # device 0 runs the fold pre-round itself (timed), then the
        # partner merges
        pre = [blocks[base_n], valids[base_n]] if rem else []
        flat = pre + [x for p in partners for x in p]
        wall_kway, (r_kw, v_kw) = timed(jax.jit(kway_chain), blocks[0],
                                        valids[0], *flat)
        # non-pow2 schedules end at 2*base*trim rows (>= S*trim): the
        # valid prefix must match the lexsort bytes, the overhang must be
        # all invalid padding
        n_g = S * trim
        r_kw, v_kw = np.asarray(r_kw), np.asarray(v_kw)
        same = bool(np.array_equal(r_kw[:n_g], np.asarray(r_lex))
                    and np.array_equal(v_kw[:n_g], np.asarray(v_lex))
                    and not v_kw[n_g:].any())
        record(f"gather_merge/shards{S}", wall_kway,
               f"lexsort_us={1e6 * wall_lex:.1f};"
               f"kway_us={1e6 * wall_kway:.1f};"
               f"speedup={wall_lex / max(wall_kway, 1e-12):.2f};"
               f"identical={int(same)}", identical=same,
               lexsort_us=1e6 * wall_lex, kway_us=1e6 * wall_kway)

    out = os.environ.get("BENCH_KERNELS_JSON", calibration.DEFAULT_FILENAME)
    with open(out, "w") as f:
        json.dump({"figure": "fig_kernels", "backend": backend,
                   "interpret": interp,
                   "sizes": {"keys": n_keys, "queries": n_q, "trim": trim},
                   "calibration": {"tile_pass_ops": tile_pass_ops,
                                   "source": source, "fitted_ops": fitted,
                                   "k_tile": DEFAULT_K_TILE,
                                   "op_s": CostModel().op_s},
                   "records": records}, f, indent=2)
    print(f"# wrote {out} ({len(records)} records)", file=sys.stderr)


# ----------------------------------------------------------------- kernels

def kernels() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    backend = jax.default_backend()
    # label with the dispatch layer's actual decision (honors ops.FORCE)
    dispatched = "pallas" if ops._use_pallas() else "jnp-oracle"

    rng = np.random.default_rng(0)
    keys = np.sort(rng.integers(0, 3_000_000, 1_000_000)).astype(np.int64)
    queries = rng.integers(0, 3_000_000, 4096).astype(np.int64)
    kj, qj = jnp.asarray(keys), jnp.asarray(queries)

    r, c = ref.sorted_probe_ref(kj, qj)
    r.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        r, c = ref.sorted_probe_ref(kj, qj)
        r.block_until_ready()
    emit("kernels/sorted_probe_ref_1Mx4k", 1e5 * (time.perf_counter() - t0),
         f"backend={backend}-jnp-oracle")

    # run_probe: the engine's hot bind-join membership probe — 4k rows,
    # each probing a window of a 1M-entry sorted column.  Timed through
    # the dispatch layer so BENCH_*.json tracks the active backend's
    # trajectory (ref today on CPU, the fused Pallas kernel on TPU).
    lo64 = rng.integers(0, 1_000_000, 4096)
    hi64 = np.minimum(1_000_000, lo64 + rng.integers(0, 100_000, 4096))
    loj, hij = jnp.asarray(lo64), jnp.asarray(hi64)
    run_probe_jit = jax.jit(ops.run_probe)
    pos, hit = run_probe_jit(kj, loj, hij, qj)
    pos.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        pos, hit = run_probe_jit(kj, loj, hij, qj)
        pos.block_until_ready()
    emit("kernels/run_probe_1Mx4k", 1e5 * (time.perf_counter() - t0),
         f"backend={backend}-{dispatched}")

    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    o = ref.attention_ref(q, k, v)
    o.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        o = ref.attention_ref(q, k, v)
        o.block_until_ready()
    emit("kernels/attention_ref_b1h4s256", 1e5 * (time.perf_counter() - t0),
         f"backend={backend}-jnp-oracle")


# ------------------------------------------------- traced serving smoke

def fig_sched_trace() -> None:
    """Serve one interleaved multi-client stream with full observability
    on and export the span timeline as a Chrome trace-event file
    (Perfetto / ``chrome://tracing`` loadable): per-query async spans
    over the ``sched.drain`` → ``wave`` → ``unit`` → ``unit.step`` /
    ``cache.probe`` / ``cache.replay_device`` hierarchy, plus
    ``kernel.*`` dispatch instants from the trace-time backend picks.

    Environment knobs (CI smoke uses the defaults):
      BENCH_TRACE_LOAD     one load name, default "union"
      BENCH_TRACE_CLIENTS  int, default 8
      BENCH_TRACE_JSON     output path, default "TRACE_sched_smoke.json"
    """
    from repro import obs
    from repro.core.scheduler import (QueryScheduler, SchedulerConfig,
                                      interleave_clients)
    from repro.core.engine import EngineConfig

    load = os.environ.get("BENCH_TRACE_LOAD", "union")
    n_clients = int(os.environ.get("BENCH_TRACE_CLIENTS", "8"))
    out = os.environ.get("BENCH_TRACE_JSON", "TRACE_sched_smoke.json")
    qs = bench_load(load)
    _, store = bench_graph()
    stream = interleave_clients(list(qs), n_clients)
    sched = QueryScheduler(store, EngineConfig(interface="spf"),
                           SchedulerConfig())
    with obs.tracing() as tracer:
        t0 = time.perf_counter()
        sched.serve(stream)
        wall = time.perf_counter() - t0
        tracer.export_chrome(out)
    emit(f"fig_sched_trace/{load}/clients{n_clients}", 1e6 * wall,
         f"events={len(tracer.events)};"
         f"waves={tracer.count('wave', 'X')};"
         f"units={tracer.count('unit', 'X')};"
         f"queries={tracer.count('query', 'b')}")
    print(f"# wrote {out} ({len(tracer.events)} events)", file=sys.stderr)


# ------------------------------------------------- the endpoint front door

def fig_endpoint() -> None:
    """Measured serving through the full SPF front door: SPARQL text in,
    parse -> star decomposition -> async endpoint loop -> scheduler
    waves, with the measured scheduler hydrated over the wire from a
    ``CacheServiceStub`` (the out-of-process cache-service deployment).
    Emits CSV rows and the ``BENCH_endpoint.json`` artifact: queries/min
    and request-latency p50/p99 vs client count plus the cache-service
    hit rate, all from ``sched.snapshot()`` diffs.

    Records carry the failure-model columns (timeouts, shed, errors,
    drain faults/retries) so a chaos run is auditable from the artifact.

    Environment knobs (CI smoke runs a single 8-client point):
      BENCH_ENDPOINT_LOAD     one load name, default "union"
      BENCH_ENDPOINT_CLIENTS  comma list, default "4,16,64"
      BENCH_ENDPOINT_JSON     output path, default "BENCH_endpoint.json"
      BENCH_ENDPOINT_CHAOS    optional seed: arm a FaultPlan (drain +
                              unit-step schedules) over the measured
                              pass — the CI chaos smoke
    """
    load = os.environ.get("BENCH_ENDPOINT_LOAD", "union")
    clients = tuple(
        int(c) for c in os.environ.get("BENCH_ENDPOINT_CLIENTS",
                                       "4,16,64").split(","))
    records = []
    for c in clients:
        r = endpoint_serve(load, c)
        r["latency_p50_ms"] = 1e3 * r.pop("latency_p50_s")
        r["latency_p99_ms"] = 1e3 * r.pop("latency_p99_s")
        records.append(r)
        emit(f"fig_endpoint/{load}/clients{c}",
             1e6 * r["wall_s"] / max(r["requests"], 1),
             f"queries_per_min={r['queries_per_min']:.1f};"
             f"p50_ms={r['latency_p50_ms']:.2f};"
             f"p99_ms={r['latency_p99_ms']:.2f};"
             f"hit_rate={r['cache_service_hit_rate']:.3f};"
             f"batches={r['batches']};"
             f"timeouts={r['timeouts']};"
             f"shed={r['shed']};"
             f"retries={r['drain_retries']};"
             f"identical={int(r['byte_identical'])}")
    out = os.environ.get("BENCH_ENDPOINT_JSON", "BENCH_endpoint.json")
    with open(out, "w") as f:
        json.dump({"figure": "fig_endpoint", "records": records}, f,
                  indent=2)
    print(f"# wrote {out} ({len(records)} records)", file=sys.stderr)


FIGS = [fig4_loadstats, fig5_throughput, fig5f_timeouts, fig6_server_load,
        fig7_network, fig8_latency, fig_sched_throughput, fig_sched_trace,
        fig_endpoint, fig_capacity, fig_dist_sched, fig_shard_sched,
        fig_live_ingest, fig_kernels, kernels]

# figures that never touch the WatDiv bench instance
_STORELESS = (fig_kernels, kernels)


def main() -> None:
    """Run all figures, or only those named on the CLI, e.g.

        python -m benchmarks.run kernels fig7_network
    """
    by_name = {f.__name__: f for f in FIGS}
    selected = sys.argv[1:]
    unknown = [n for n in selected if n not in by_name]
    if unknown:
        raise SystemExit(f"unknown figure(s) {unknown}; "
                         f"choose from {sorted(by_name)}")
    figs = [by_name[n] for n in selected] if selected else FIGS
    if any(f not in _STORELESS for f in figs):
        g, store = bench_graph()
        print(f"# WatDiv bench instance: {store.n_triples} triples, "
              f"{store.n_predicates} predicates")
    print("name,us_per_call,derived")
    for fig in figs:
        fig()


if __name__ == "__main__":
    main()
