"""Shared benchmark setup: one WatDiv instance + query loads per process."""

from __future__ import annotations

import time
from functools import lru_cache

from repro.configs.spf_watdiv import BENCH_GRAPH
from repro.core import EngineConfig, QueryEngine, QueryScheduler
from repro.core.scheduler import SchedulerConfig, interleave_clients
from repro.rdf import TripleStore, generate_query_load, generate_watdiv
from repro.rdf.queries import QueryLoadConfig

LOADS = ("1-star", "2-stars", "3-stars", "paths", "union")
INTERFACES = ("tpf", "brtpf", "spf", "endpoint")
N_QUERIES = 6
CLIENTS = (1, 4, 16, 64, 128)
SCHED_CLIENTS = (16, 64, 128)  # scheduler-vs-serial load points


@lru_cache(maxsize=1)
def bench_graph():
    g = generate_watdiv(BENCH_GRAPH)
    store = TripleStore.build(g.s, g.p, g.o, n_terms=g.n_terms,
                              n_predicates=g.n_predicates)
    return g, store


@lru_cache(maxsize=None)
def bench_load(load: str):
    g, store = bench_graph()
    return generate_query_load(g, store, load,
                               QueryLoadConfig(n_queries=N_QUERIES))


@lru_cache(maxsize=None)
def engine(interface: str) -> QueryEngine:
    _, store = bench_graph()
    return QueryEngine(store, EngineConfig(interface=interface))


def timed_run(eng: QueryEngine, q, repeats: int = 3):
    """(wall seconds per run after warmup, stats)."""
    tbl, stats = eng.run(q)  # warmup + compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        tbl, stats = eng.run(q)
        tbl.rows.block_until_ready()
    return (time.perf_counter() - t0) / repeats, stats


@lru_cache(maxsize=None)
def load_run(load: str, interface: str):
    """Memoised (mean wall seconds, tuple of per-query stats) — every
    figure reads from this one execution of the load."""
    qs = bench_load(load)
    eng = engine(interface)
    wall, stats = 0.0, []
    for q in qs:
        sec, st = timed_run(eng, q, repeats=1)
        wall += sec
        stats.append(st)
    return wall / len(qs), tuple(stats)


def sched_vs_serial(load: str, n_clients: int, interface: str = "spf",
                    lanes: int = 16, serial_reps: int = 2):
    """Serve ``n_clients`` interleaved copies of a load both ways, warm.

    The scheduler path serves the *full* request stream for real.  The
    serial baseline is measured per distinct query over ``serial_reps``
    warm repetitions and extrapolated to ``n_clients`` executions — the
    serial loop runs each request independently, so its wall time is
    linear in the client count by construction (a full 128-client serial
    replay of the union load would take the better part of an hour).

    Returns a dict with wall seconds for the stream on both paths, the
    fragment-cache hit rate, measured occupancy, per-query latency
    quantiles (from the registry's ``sched.query_latency_s`` histogram,
    observed with registry-only observability enabled around the measured
    pass — no tracer, so no fences perturb the wall), and the
    byte-identity flag the acceptance gate checks.  Compile cost is paid
    before timing on both paths (one warm pass each; measured rates come
    from a registry snapshot diff over the measured pass only, so the
    warm pass never leaks into them — the capacity-hint memo, which is
    scheduler state rather than cache content, stays warm like the
    serial engine's jit cache does).
    """
    import numpy as np

    from repro import obs
    from repro.core import results_as_numpy

    qs = bench_load(load)
    stream = interleave_clients(list(qs), n_clients)
    cfg = EngineConfig(interface=interface)
    eng = engine(interface)

    # --- serial path: per-query warm time x client count ----------------
    serial_out = [eng.run(q) for q in qs]  # warm compile per signature
    serial_s = 0.0
    for q in qs:
        t0 = time.perf_counter()
        for _ in range(serial_reps):
            tbl, _ = eng.run(q)
            tbl.rows.block_until_ready()
        serial_s += (time.perf_counter() - t0) / serial_reps * n_clients

    # --- scheduler path: the real stream --------------------------------
    sched = QueryScheduler(bench_graph()[1], cfg,
                           SchedulerConfig(lanes=lanes))
    sched.serve(stream)  # warm compile of the unit steps
    sched.cache.clear()
    base = sched.snapshot()
    with obs.tracing(trace=False):  # registry-only: latency, no fences
        t0 = time.perf_counter()
        sched_out = sched.serve(stream)
        sched_s = time.perf_counter() - t0
    diff = sched.snapshot() - base

    identical = all(
        np.array_equal(results_as_numpy(serial_out[i // n_clients][0]),
                       results_as_numpy(tbl))
        for i, (tbl, _) in enumerate(sched_out))
    hits = diff.scalar("cache.hits") + diff.scalar("cache.shared_hits")
    probes = hits + diff.scalar("cache.misses")
    steps = diff.scalar("sched.steps")
    lat = diff.get("sched.query_latency_s", {})
    return {
        "load": load, "interface": interface, "clients": n_clients,
        "requests": len(stream), "serial_s": serial_s, "sched_s": sched_s,
        "speedup": serial_s / sched_s if sched_s else float("inf"),
        "hit_rate": hits / probes if probes else 0.0,
        "occupancy": diff.scalar("sched.active_lane_steps") / steps
        if steps else 0.0,
        "latency_p50_s": lat.get("p50", 0.0),
        "latency_p99_s": lat.get("p99", 0.0),
        "byte_identical": bool(identical),
        "stats": [st for _, st in sched_out],
    }


def capacity_planner_vs_blind(load: str = "union", n_queries: int | None = None,
                              interface: str = "spf", repeats: int = 2):
    """Warm-run wall with the capacity planner on vs off (``fig_capacity``).

    Planner off is the blind whole-query 4x retry ladder: every warm run
    of an overflowing query re-climbs every rung, re-executing every unit
    at every one of them.  Planner on sizes each unit from high-water
    marks/degree bounds and resumes overflow at the failing unit, so a
    warm run executes each unit exactly once at its observed rung.

    Per the bench-scale protocol the measurement samples per-query warm
    runs (``benchlib.warm_run_wall``) and extrapolates to the load — full
    client streams are never replayed serially.  Returns one record per
    query (walls, speedup, whether the query overflows the base capacity,
    and the byte-identity flag the acceptance gate checks) plus the
    extrapolated load walls.
    """
    import numpy as np

    from repro.benchlib import warm_run_wall
    from repro.core import results_as_numpy

    qs = bench_load(load)[:n_queries]
    _, store = bench_graph()
    blind_cfg = EngineConfig(interface=interface, capacity_planner=False)
    planned_cfg = EngineConfig(interface=interface)
    _, blind_walls, blind_out = warm_run_wall(store, qs, cfg=blind_cfg,
                                              repeats=repeats)
    planned_eng, planned_walls, planned_out = warm_run_wall(
        store, qs, cfg=planned_cfg, repeats=repeats)

    records = []
    for i, q in enumerate(qs):
        (b_tbl, b_st), (p_tbl, p_st) = blind_out[i], planned_out[i]
        identical = (np.array_equal(results_as_numpy(b_tbl),
                                    results_as_numpy(p_tbl))
                     and tuple(int(x) for x in b_st)[:6]
                     == tuple(int(x) for x in p_st)[:6])
        caps = planned_eng.planner.unit_caps(planned_eng.plan(q))
        records.append({
            "query": i,
            "blind_s": blind_walls[i],
            "planned_s": planned_walls[i],
            "speedup": blind_walls[i] / planned_walls[i]
            if planned_walls[i] else float("inf"),
            "max_unit_cap": max(caps, default=planned_cfg.cap),
            "overflows_base_cap": max(caps, default=0) > planned_cfg.cap,
            "byte_identical": bool(identical),
        })
    ovf = [r for r in records if r["overflows_base_cap"]] or records
    return {
        "load": load, "interface": interface, "n_queries": len(qs),
        "repeats": repeats,
        "extrapolated_load_blind_s": float(np.mean(blind_walls) * len(qs)),
        "extrapolated_load_planned_s": float(np.mean(planned_walls) * len(qs)),
        # the acceptance gate ("a union-load overflow query no longer
        # re-executes the ladder: >= 5x warm"): best single overflow query
        "best_overflow_speedup": float(max(r["speedup"] for r in ovf)),
        "mean_overflow_speedup": float(np.mean([r["speedup"] for r in ovf])),
        "byte_identical": all(r["byte_identical"] for r in records),
        "records": records,
    }


def endpoint_serve(load: str, n_clients: int, interface: str = "endpoint",
                   lanes: int = 16, wave_budget: int = 64):
    """One ``fig_endpoint`` measurement point: the full front door.

    ``n_clients`` async clients each submit the load *as SPARQL text*
    through ``EndpointService`` (parse -> star decomposition -> scheduler
    waves).  The measured scheduler is a **fresh** one hydrated from a
    ``CacheServiceStub`` warmed by a donor scheduler — every fragment it
    serves from cache crossed the wire format, which is exactly the
    out-of-process cache-service deployment the endpoint targets.  The
    donor pass also pays all compile cost, so the measured wall is warm.

    Returns a record with measured queries/min, request-latency p50/p99
    (from the obs-gated ``endpoint.latency_s`` histogram, registry-only
    observability — no tracer fences), the cache-service hit rate,
    interface NRS/NTB and the failure-model columns (timeouts, shed,
    drain faults/retries) — all read from ``sched.snapshot()`` diffs
    over the measured pass, plus the byte-identity flag against the
    serial engine.

    Set ``BENCH_ENDPOINT_CHAOS=<seed>`` to arm a seeded ``FaultPlan``
    (drain + unit-step raise schedules) over the measured pass: the
    chaos smoke.  Under chaos, byte-identity is asserted over the
    ``"ok"`` responses (faulted requests legitimately resolve
    ``"error"``); disarmed, it additionally requires every request to
    be ``"ok"``.
    """
    import contextlib
    import os

    import numpy as np

    from repro import faults, obs
    from repro.core import results_as_numpy
    from repro.endpoint import CacheServiceStub, to_sparql
    from repro.endpoint.service import (EndpointRequest, EndpointService,
                                        ServiceConfig)

    qs = bench_load(load)
    _, store = bench_graph()
    cfg = EngineConfig(interface=interface)
    # cap_hints off keeps request keys identical between the donor and
    # the hydrated scheduler (the cache-service sharing configuration)
    scfg = SchedulerConfig(lanes=lanes, cap_hints=False)
    svc_cfg = ServiceConfig(max_inflight_per_client=len(qs),
                            wave_budget=wave_budget)
    texts = [to_sparql(q) for q in qs]
    reqs = [EndpointRequest(client=c, sparql=t)
            for c in range(n_clients) for t in texts]

    # serial reference rows (byte-identity check at the interface)
    eng = engine(interface)
    want = {t: results_as_numpy(eng.run(q)[0]) for t, q in zip(texts, qs)}

    # donor: compiles the unit steps, fills cache + HWM, deposits bytes
    donor = QueryScheduler(store, cfg, scfg)
    EndpointService(donor, svc_cfg).serve(reqs)
    stub = CacheServiceStub()
    service_bytes = stub.deposit(donor.cache, donor.planner,
                                 epoch=store.epoch)

    # measured: a fresh scheduler hydrated from the cache service
    sched = QueryScheduler(store, cfg, scfg)
    stub.hydrate(sched.cache, sched.planner, epoch=store.epoch)
    svc = EndpointService(sched, svc_cfg)
    chaos_seed = os.environ.get("BENCH_ENDPOINT_CHAOS")
    if chaos_seed is not None:
        chaos = faults.injecting(faults.FaultPlan(int(chaos_seed), {
            "drain": faults.FaultSpec("raise", p=0.10),
            "unit.step": faults.FaultSpec("raise", p=0.05),
        }))
    else:
        chaos = contextlib.nullcontext()
    base = sched.snapshot()
    with chaos, obs.tracing(trace=False):  # registry-only: no fences
        t0 = time.perf_counter()
        resps = svc.serve(reqs)
        wall = time.perf_counter() - t0
    diff = sched.snapshot() - base

    served = diff.scalar("endpoint.served")
    ok = [(r, req) for r, req in zip(resps, reqs) if r.status == "ok"]
    identical = all(r.rows.tobytes() == want[req.sparql].tobytes()
                    for r, req in ok)
    if chaos_seed is None:
        identical = identical and len(ok) == len(reqs)
    hits = diff.scalar("cache.hits") + diff.scalar("cache.shared_hits")
    probes = hits + diff.scalar("cache.misses")
    lat = diff.get("endpoint.latency_s", {})
    return {
        "load": load, "interface": interface, "clients": n_clients,
        "requests": len(reqs), "served": served,
        "rejected": diff.scalar("endpoint.rejected"),
        "shed": diff.scalar("endpoint.shed"),
        "timeouts": diff.scalar("endpoint.timeouts"),
        "errors": diff.scalar("endpoint.errors"),
        "drain_faults": diff.scalar("endpoint.drain_faults"),
        "drain_retries": diff.scalar("endpoint.drain_retries"),
        "chaos_seed": int(chaos_seed) if chaos_seed is not None else None,
        "batches": diff.scalar("endpoint.batches"),
        "wall_s": wall,
        "queries_per_min": served * 60.0 / wall if wall else 0.0,
        "latency_p50_s": lat.get("p50", 0.0),
        "latency_p99_s": lat.get("p99", 0.0),
        "cache_service_hit_rate": hits / probes if probes else 0.0,
        "cache_service_bytes": service_bytes,
        "nrs": diff.scalar("endpoint.nrs"),
        "ntb": diff.scalar("endpoint.ntb"),
        "byte_identical": bool(identical),
    }


def live_ingest_serve(load: str, n_clients: int, rate_pct: float,
                      rounds: int = 3, interface: str = "spf",
                      lanes: int = 16, n_hot_preds: int = 2, seed: int = 0):
    """One ``fig_live_ingest`` measurement point: serve a multi-client
    stream through ``rounds`` consecutive write windows at a sustained
    write rate of ``rate_pct`` percent of the store per window, on both
    serving modes:

    - **live**: each window's writes land as a delta batch through
      ``sched.ingest`` (sorted insert/tombstone overlay, merged
      base+delta probes, epoch-pipelined waves, cache/HWM carry-over,
      ``maybe_compact`` past the fold threshold) and the *same*
      scheduler keeps serving;
    - **rebuild**: the stop-the-world baseline — each window pays a full
      ``TripleStore.build`` of the merged triple set and a fresh
      scheduler (cold fragment cache) before serving.

    Both paths replay the *same* delta batches, so every window's
    logical store is identical and the byte-identity flag compares the
    two paths' results window by window.  Writes follow the append-feed
    shape of real KG write loads: ~90% of each window lands on
    ``n_hot_preds`` *feed* predicates (the most populated ones outside
    the query load's constant predicates — ingest feeds are typically
    disjoint from the analytic working set), and ~10% on one uniformly
    drawn *stray* predicate per window, so a share of windows does
    intersect the read working set and pays the recompute + sweep that
    any system pays when reads meet writes.  Carry-over is what the
    live path exploits on the rest: fragments and high-water marks over
    untouched predicates survive each delta epoch.  The throughput
    quotient counts the write-application cost on both paths (delta
    apply + occasional compaction vs full rebuild) — it is *sustained*
    queries/min under writes, not a cache microbench.
    """
    import numpy as np

    from repro import obs
    from repro.core import results_as_numpy

    qs = bench_load(load)
    g, _ = bench_graph()
    stream = interleave_clients(list(qs), n_clients)
    cfg = EngineConfig(interface=interface)
    scfg = SchedulerConfig(lanes=lanes)
    rng = np.random.default_rng(seed)

    def fresh_store():
        # private copies: the delta evolution must never leak into the
        # memoised bench instance other figures read
        return TripleStore.build(g.s, g.p, g.o, n_terms=g.n_terms,
                                 n_predicates=g.n_predicates)

    live = fresh_store()
    n0 = live.n_triples
    n_delta = max(4, int(rate_pct / 100.0 * n0))
    # feed predicates: most populated outside the load's constants
    counts = np.bincount(np.asarray(g.p), minlength=g.n_predicates)
    load_preds = {t.p.id for q in qs for t in q.patterns if not t.p.is_var}
    feed = np.array([p for p in np.argsort(counts)[::-1]
                     if int(p) not in load_preds][:n_hot_preds])

    def make_batch(store):
        stray = int(rng.integers(0, g.n_predicates))
        n_stray = max(1, n_delta // 10)
        ms, mp, mo = store.merged_triples()
        pool = np.nonzero(np.isin(mp, np.append(feed, stray)))[0]
        n_del = min(n_delta // 2, pool.size)
        idx = rng.choice(pool, n_del, replace=False)
        n_ins = n_delta - n_del
        preds = np.where(np.arange(n_ins) < n_stray, stray,
                         feed[rng.integers(0, feed.size, n_ins)])
        ins = (rng.integers(0, g.n_terms, n_ins), preds,
               rng.integers(0, g.n_terms, n_ins))
        return dict(insert=ins, delete=(ms[idx], mp[idx], mo[idx]))

    # --- live path: one scheduler serving through the writes ------------
    sched = QueryScheduler(live, cfg, scfg)
    sched.serve(stream)  # warm compile + fill the cache
    # steady-state priming (untimed, like every warm pass here): the
    # first write flips the unit steps from the no-delta fast path to
    # the merged base+delta trace; the store pads the delta to one
    # stable bucket, so this single compile covers every later delta
    # epoch until compaction.  The baseline is symmetric — its rebuilt
    # stores keep the warmed shapes of the pre-write pass.
    prime = make_batch(live)
    sched.ingest(**prime)
    sched.serve(stream)
    batches, live_out = [], []
    c0 = (sched.cache.stats.carryover, sched.cache.stats.swept,
          sched.planner.stats.carryover)
    base_snap = sched.snapshot()
    live_s = ingest_s = 0.0
    compactions = 0
    with obs.tracing(trace=False):  # registry-only: latency, no fences
        for _ in range(rounds):
            batch = make_batch(live)
            batches.append(batch)
            t0 = time.perf_counter()
            sched.ingest(**batch)
            if live.maybe_compact(frac=0.25):
                compactions += 1
                sched._refresh_epoch()
            ingest_s += time.perf_counter() - t0
            t0 = time.perf_counter()
            live_out.append(sched.serve(stream))
            live_s += time.perf_counter() - t0
    diff = sched.snapshot() - base_snap
    carry = sched.cache.stats.carryover - c0[0]
    swept = sched.cache.stats.swept - c0[1]
    hwm_carry = sched.planner.stats.carryover - c0[2]
    lat = diff.get("sched.query_latency_s", {})

    # --- rebuild baseline: stop-the-world per window ---------------------
    shadow = fresh_store()  # bookkeeping only: replays the batches
    shadow.apply_delta(**prime)
    ms, mp, mo = shadow.merged_triples()
    bstore = TripleStore.build(ms, mp, mo, n_terms=g.n_terms,
                               n_predicates=g.n_predicates)
    bsched = QueryScheduler(bstore, cfg, scfg)
    bsched.serve(stream)  # warm compile at the primed store's shapes
    base_out = []
    rebuild_s = build_s = 0.0
    for batch in batches:
        shadow.apply_delta(**batch)
        ms, mp, mo = shadow.merged_triples()
        t0 = time.perf_counter()
        bstore = TripleStore.build(ms, mp, mo, n_terms=g.n_terms,
                                   n_predicates=g.n_predicates)
        bsched = QueryScheduler(bstore, cfg, scfg)
        build_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        base_out.append(bsched.serve(stream))
        rebuild_s += time.perf_counter() - t0

    identical = all(
        np.array_equal(results_as_numpy(a), results_as_numpy(b))
        for lo, bo in zip(live_out, base_out)
        for (a, _), (b, _) in zip(lo, bo))
    n_served = rounds * len(stream)
    live_total = live_s + ingest_s
    rebuild_total = rebuild_s + build_s
    return {
        "load": load, "interface": interface, "clients": n_clients,
        "rate_pct_per_window": rate_pct, "rounds": rounds,
        "requests_per_window": len(stream),
        "delta_triples_per_window": n_delta,
        "store_triples": n0,
        "feed_predicates": [int(p) for p in feed],
        "live_serve_s": live_s, "live_ingest_s": ingest_s,
        "live_total_s": live_total,
        "rebuild_serve_s": rebuild_s, "rebuild_build_s": build_s,
        "rebuild_total_s": rebuild_total,
        "speedup": rebuild_total / live_total if live_total
        else float("inf"),
        "live_queries_per_min": n_served * 60.0 / live_total
        if live_total else 0.0,
        "rebuild_queries_per_min": n_served * 60.0 / rebuild_total
        if rebuild_total else 0.0,
        "latency_p50_s": lat.get("p50", 0.0),
        "latency_p99_s": lat.get("p99", 0.0),
        "compactions": compactions,
        "cache_carryover": int(carry), "cache_swept": int(swept),
        "planner_carryover": int(hwm_carry),
        "byte_identical": bool(identical),
    }


def sched_mesh_vs_vmap(load: str, n_clients: int, interface: str = "spf",
                       lanes: int = 16):
    """Serve one interleaved multi-client stream through both wave
    lowerings: single-host vmap waves and mesh-spanning shard_map waves
    (``fig_dist_sched``'s measurement).

    Request collapsing is disabled on both paths so every client request
    occupies a lane — that is the configuration under which wave width
    reaches the mesh's lane-slot count and the per-wave mesh-vs-vmap pick
    actually engages (with collapsing on, duplicate requests fold onto
    one lane and buckets stay narrow).  Compile cost is paid by a warm
    pass on each path; the fragment cache is cleared and all measured
    rates come from a registry snapshot diff over the measured pass.
    Returns a record with wall seconds for both paths, the mesh-wave
    fraction, cache hit rate, occupancy and the byte-identity flag
    between the two paths' results (the acceptance invariant: mesh
    routing changes placement, never bytes).
    """
    import jax
    import numpy as np

    from repro.core import results_as_numpy

    qs = bench_load(load)
    _, store = bench_graph()
    stream = interleave_clients(list(qs), n_clients)
    cfg = EngineConfig(interface=interface)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("model",))
    lanes = max(lanes, n_dev)

    out, wall, diff_of = {}, {}, {}
    for name, m in (("vmap", None), ("mesh", mesh)):
        sched = QueryScheduler(
            store, cfg,
            SchedulerConfig(lanes=lanes, collapse_duplicates=False), mesh=m)
        sched.serve(stream)  # warm compile of this lowering's unit steps
        sched.cache.clear()
        base = sched.snapshot()
        t0 = time.perf_counter()
        out[name] = sched.serve(stream)
        wall[name] = time.perf_counter() - t0
        diff_of[name] = sched.snapshot() - base

    identical = all(
        np.array_equal(results_as_numpy(a), results_as_numpy(b))
        and tuple(int(x) for x in sa)[:6] == tuple(int(x) for x in sb)[:6]
        for (a, sa), (b, sb) in zip(out["vmap"], out["mesh"]))
    d = diff_of["mesh"]
    steps = d.scalar("sched.steps")
    hits = d.scalar("cache.hits") + d.scalar("cache.shared_hits")
    probes = hits + d.scalar("cache.misses")
    return {
        "load": load, "interface": interface, "clients": n_clients,
        "requests": len(stream), "n_devices": n_dev, "lanes": lanes,
        "vmap_s": wall["vmap"], "mesh_s": wall["mesh"],
        "mesh_vs_vmap": wall["vmap"] / wall["mesh"] if wall["mesh"]
        else float("inf"),
        "mesh_wave_fraction": d.scalar("sched.mesh_steps") / steps
        if steps else 0.0,
        "hit_rate": hits / probes if probes else 0.0,
        "occupancy": d.scalar("sched.active_lane_steps") / steps
        if steps else 0.0,
        # replicated lanes move no per-unit gather traffic; recorded so
        # the artifact schema matches the sharded figure's records and
        # the transfer models stay comparable
        "gather_bytes": d.scalar("sched.gather_bytes"),
        "byte_identical": bool(identical),
        "stats": [st for _, st in out["mesh"]],
    }


def sched_shard_vs_replicated(load: str, n_clients: int, n_shards: int,
                              interface: str = "spf", lanes: int = 16):
    """Serve one interleaved multi-client stream through sharded-store
    scheduler waves vs replicated mesh waves (``fig_shard_sched``).

    The sharded scheduler gets a ``(data=n_shards, model=n_dev/n_shards)``
    mesh with the store subject-hash sharded along ``data`` (1/n_shards of
    the index per device); the replicated baseline spans all devices as
    lanes with the full store on each.  Collapsing is off on both paths
    so wave width reaches the lane-slot counts.  Records wall seconds for
    both, the *per-device store bytes* of each placement (the figure's
    headline: sharded bytes shrink ~linearly with the shard count), the
    sharded path's measured per-unit gather traffic, hit rate, occupancy
    and the byte-identity flag between the two paths' results + gross
    stats (the acceptance invariant: shard count is invisible in bytes).
    """
    import jax
    import numpy as np

    from repro.core import results_as_numpy

    qs = bench_load(load)
    _, store = bench_graph()
    stream = interleave_clients(list(qs), n_clients)
    cfg = EngineConfig(interface=interface)
    n_dev = len(jax.devices())
    if n_dev % n_shards:
        raise ValueError(f"n_shards {n_shards} must divide the device "
                         f"count {n_dev}")
    mesh_rep = jax.make_mesh((n_dev,), ("model",))
    mesh_sh = jax.make_mesh((n_shards, n_dev // n_shards),
                            ("data", "model"))
    lanes = max(lanes, n_dev)

    out, wall, sched_of, diff_of = {}, {}, {}, {}
    for name, m, ax in (("replicated", mesh_rep, None),
                        ("sharded", mesh_sh, "data")):
        sched = QueryScheduler(
            store, cfg,
            SchedulerConfig(lanes=lanes, collapse_duplicates=False),
            mesh=m, data_axis=ax)
        sched.serve(stream)  # warm compile of this lowering's unit steps
        sched.cache.clear()
        base = sched.snapshot()
        t0 = time.perf_counter()
        out[name] = sched.serve(stream)
        wall[name] = time.perf_counter() - t0
        sched_of[name] = sched
        diff_of[name] = sched.snapshot() - base

    identical = all(
        np.array_equal(results_as_numpy(a), results_as_numpy(b))
        and tuple(int(x) for x in sa)[:6] == tuple(int(x) for x in sb)[:6]
        for (a, sa), (b, sb) in zip(out["replicated"], out["sharded"]))
    d = diff_of["sharded"]
    full_bytes = sum(int(np.asarray(a).nbytes) for a in store.device)
    stacked = sched_of["sharded"]._stacked
    shard_bytes = sum(int(np.asarray(a).nbytes) for a in stacked) // n_shards
    return {
        "load": load, "interface": interface, "clients": n_clients,
        "requests": len(stream), "n_devices": n_dev, "n_shards": n_shards,
        "lanes": lanes,
        "replicated_s": wall["replicated"], "sharded_s": wall["sharded"],
        "sharded_vs_replicated": wall["replicated"] / wall["sharded"]
        if wall["sharded"] else float("inf"),
        "store_bytes_per_device_replicated": full_bytes,
        "store_bytes_per_device_sharded": shard_bytes,
        "store_bytes_shrink": full_bytes / shard_bytes if shard_bytes
        else float("inf"),
        "shard_wave_fraction": d.scalar("sched.shard_steps")
        / d.scalar("sched.steps") if d.scalar("sched.steps") else 0.0,
        "gather_bytes": d.scalar("sched.gather_bytes"),
        "hit_rate": (d.scalar("cache.hits") + d.scalar("cache.shared_hits"))
        / max(d.scalar("cache.hits") + d.scalar("cache.shared_hits")
              + d.scalar("cache.misses"), 1),
        "occupancy": d.scalar("sched.active_lane_steps")
        / d.scalar("sched.steps") if d.scalar("sched.steps") else 0.0,
        "byte_identical": bool(identical),
        "stats": [st for _, st in out["sharded"]],
    }
