"""Shared benchmark setup: one WatDiv instance + query loads per process."""

from __future__ import annotations

import time
from functools import lru_cache

from repro.configs.spf_watdiv import BENCH_GRAPH
from repro.core import EngineConfig, QueryEngine
from repro.rdf import TripleStore, generate_query_load, generate_watdiv
from repro.rdf.queries import QueryLoadConfig

LOADS = ("1-star", "2-stars", "3-stars", "paths", "union")
INTERFACES = ("tpf", "brtpf", "spf", "endpoint")
N_QUERIES = 6
CLIENTS = (1, 4, 16, 64, 128)


@lru_cache(maxsize=1)
def bench_graph():
    g = generate_watdiv(BENCH_GRAPH)
    store = TripleStore.build(g.s, g.p, g.o, n_terms=g.n_terms,
                              n_predicates=g.n_predicates)
    return g, store


@lru_cache(maxsize=None)
def bench_load(load: str):
    g, store = bench_graph()
    return generate_query_load(g, store, load,
                               QueryLoadConfig(n_queries=N_QUERIES))


@lru_cache(maxsize=None)
def engine(interface: str) -> QueryEngine:
    _, store = bench_graph()
    return QueryEngine(store, EngineConfig(interface=interface))


def timed_run(eng: QueryEngine, q, repeats: int = 3):
    """(wall seconds per run after warmup, stats)."""
    tbl, stats = eng.run(q)  # warmup + compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        tbl, stats = eng.run(q)
        tbl.rows.block_until_ready()
    return (time.perf_counter() - t0) / repeats, stats


@lru_cache(maxsize=None)
def load_run(load: str, interface: str):
    """Memoised (mean wall seconds, tuple of per-query stats) — every
    figure reads from this one execution of the load."""
    qs = bench_load(load)
    eng = engine(interface)
    wall, stats = 0.0, []
    for q in qs:
        sec, st = timed_run(eng, q, repeats=1)
        wall += sec
        stats.append(st)
    return wall / len(qs), tuple(stats)
